"""Serving-layer study: dynamic batching vs serial dispatch under Zipf
traffic, and mid-traffic compaction safety.

Two configurations of the same :class:`repro.serve.AlignServer` over the
same live store:

* **serial**  — ``max_batch=1`` (every request probes alone; what a
  naive per-request handler does);
* **batched** — ``max_batch=32`` with a short linger (the dynamic
  micro-batcher coalesces concurrent arrivals into ``find_batch``
  calls).

Methodology:

* **closed-loop** (C virtual clients, each waiting for its response
  before sending the next): throughput + latency table at C=1 and C=16.
  At C=1 the two configs are near-identical — batching costs nothing
  when there is nothing to coalesce.
* **open-loop** (arrivals at a fixed rate, independent of completions —
  the traffic model that actually exposes tail latency): arrival rate is
  calibrated to ~1.3x the *serial* server's measured closed-loop
  capacity, so the serial config saturates and its queue grows while the
  batched config (several-fold the per-request throughput) keeps up.
  Claim ``server_p99_batched_le_serial``: batched p99 <= serial p99 with
  >= 16 requests in flight.
* **promotion soak**: continuous traffic against the batched server
  while a ``/compact`` folds a pre-loaded delta into a new promoted
  store generation mid-stream.  Claim
  ``no_dropped_requests_across_promotion``: every request sent is
  answered OK (no drops, no errors, no 5xx) and every response is
  bit-identical to a from-scratch oracle over the union corpus —
  responses before, during, and after the pointer flip.

    PYTHONPATH=src python -m benchmarks.bench_serve [--full] [--smoke]
"""

from __future__ import annotations

import asyncio
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.api import Aligner

from .common import print_table, save_result, zipf_text

THETA = 0.7
K = 16


def _corpus(n_docs: int, doc_len: int, seed: int = 0):
    """Zipf token stream chopped into documents."""
    stream = zipf_text(n_docs * doc_len, seed=seed)
    return [stream[i * doc_len:(i + 1) * doc_len].copy()
            for i in range(n_docs)]


def _uniform_corpus(n_docs: int, doc_len: int, seed: int = 0):
    """Uniform-token documents (selective matching for the multiset
    scheme of the promotion soak, where the oracle needs a corpus-free
    weight function)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 1 << 40, size=doc_len)
            for _ in range(n_docs)]


def _queries(docs, n_q: int, q_len: int, seed: int = 1,
             dup_every: int = 4):
    """Serving mix: 1 in ``dup_every`` queries is a snippet of a corpus
    document (Zipf popularity — most hits land on a few hot docs), the
    rest are novel text that should probe and miss."""
    rng = np.random.default_rng(seed)
    pop = np.minimum(rng.zipf(1.3, size=n_q) - 1, len(docs) - 1)
    out = []
    for i in range(n_q):
        if i % dup_every == 0:
            d = docs[int(pop[i])]
            lo = int(rng.integers(0, max(1, len(d) - q_len)))
            out.append([int(t) for t in d[lo:lo + q_len]])
        else:
            out.append([int(t) for t in
                        rng.integers(0, 1 << 40, size=q_len)])
    return out


async def _closed_loop(port: int, queries, concurrency: int,
                       duration_s: float):
    """C clients, one keep-alive connection each, back-to-back requests;
    returns (qps, lat_list_seconds, n_errors)."""
    from repro.serve.client import AsyncAlignClient
    loop = asyncio.get_running_loop()
    lats, errors = [], [0]
    stop = loop.time() + duration_s

    async def worker(w: int):
        c = await AsyncAlignClient.connect("127.0.0.1", port)
        i = w
        try:
            while loop.time() < stop:
                t0 = loop.time()
                status, _ = await c.query(queries[i % len(queries)], THETA)
                if status != 200:
                    errors[0] += 1
                else:
                    lats.append(loop.time() - t0)
                i += concurrency
        finally:
            await c.close()

    t0 = loop.time()
    await asyncio.gather(*(worker(w) for w in range(concurrency)))
    dt = loop.time() - t0
    return len(lats) / dt, lats, errors[0]


async def _open_loop(port: int, queries, rate_qps: float,
                     duration_s: float, conns: int = 4):
    """Fixed-rate arrivals over pipelined WebSocket connections; latency
    is measured from the *scheduled* arrival (true open-loop: a saturated
    server accrues queue delay into the tail).  Returns
    (lat_list, n_bad, n_sent, max_inflight)."""
    from repro.serve.client import AsyncWSClient
    loop = asyncio.get_running_loop()
    clients = [await AsyncWSClient.connect("127.0.0.1", port)
               for _ in range(conns)]
    lats, bad = [], [0]
    inflight, max_inflight = [0], [0]
    pending = []
    t0 = loop.time()
    n = int(rate_qps * duration_s)

    def done(sched):
        def cb(fut):
            inflight[0] -= 1
            msg = fut.result() if not fut.cancelled() else None
            if msg is None or not msg.get("ok", False):
                bad[0] += 1
            else:
                lats.append(loop.time() - sched)
        return cb

    for i in range(n):
        sched = t0 + i / rate_qps
        now = loop.time()
        if sched > now:
            await asyncio.sleep(sched - now)
        fut = clients[i % conns].submit(queries[i % len(queries)], THETA)
        inflight[0] += 1
        max_inflight[0] = max(max_inflight[0], inflight[0])
        fut.add_done_callback(done(sched))
        pending.append(fut)
    await asyncio.gather(*pending, return_exceptions=True)
    for c in clients:
        await c.close()
    return lats, bad[0], n, max_inflight[0]


def _pct(lats, q: float) -> float:
    if not lats:
        return float("inf")
    return float(np.percentile(np.asarray(lats), q))


async def _bench_config(store: str, queries, *, max_batch: int,
                        linger_us: float, closed_cs, closed_s: float,
                        open_rate: float | None, open_s: float):
    """One server config: closed-loop rows (+ calibration qps) and an
    optional open-loop row."""
    from repro.serve import AlignServer
    aligner = Aligner.load(store, live=True)
    rows, open_row = [], None
    async with AlignServer(aligner, max_batch=max_batch,
                           max_linger_us=linger_us,
                           queue_cap=1_000_000) as srv:
        # warm-up: page in the arena, build the engine thread
        await _closed_loop(srv.port, queries[:8], 2, 0.2)
        for c in closed_cs:
            qps, lats, nerr = await _closed_loop(srv.port, queries, c,
                                                 closed_s)
            rows.append({"mode": "closed", "batching": max_batch > 1,
                         "concurrency": c, "qps": qps,
                         "p50_ms": 1e3 * _pct(lats, 50),
                         "p99_ms": 1e3 * _pct(lats, 99), "errors": nerr})
        if open_rate is not None:
            lats, bad, sent, peak = await _open_loop(srv.port, queries,
                                                     open_rate, open_s)
            open_row = {"mode": "open", "batching": max_batch > 1,
                        "rate_qps": open_rate, "sent": sent,
                        "p50_ms": 1e3 * _pct(lats, 50),
                        "p99_ms": 1e3 * _pct(lats, 99),
                        "bad": bad, "peak_inflight": peak}
            srv_metrics = srv.metrics.snapshot()
            open_row["batch_p50"] = srv_metrics["batch_size"]["p50"]
    return rows, open_row


async def _promotion_soak(store: str, docs, delta_docs, queries,
                          duration_s: float):
    """Traffic + one mid-stream compaction; every response checked
    bit-identical against a from-scratch oracle of the union corpus."""
    from repro.serve import AlignServer
    from repro.serve.client import AsyncAlignClient, AsyncWSClient

    aligner = Aligner.load(store, live=True)
    loop = asyncio.get_running_loop()
    oracle = Aligner.build(docs + delta_docs, similarity="multiset",
                           seed=2, k=K, pipeline="columnar")
    expected = [r.to_dict() for r in oracle.find_batch(queries, THETA)]

    sent, mismatches, bad = [0], [0], [0]
    async with AlignServer(aligner, max_batch=32, max_linger_us=1000,
                           queue_cap=1_000_000) as srv:
        ctl = await AsyncAlignClient.connect("127.0.0.1", srv.port)
        for d in delta_docs:                       # pre-load the delta
            await ctl.add(d)
        ws = await AsyncWSClient.connect("127.0.0.1", srv.port)
        stop = loop.time() + duration_s
        gen_before = (await ctl.request("GET", "/healthz"))[1]["generation"]
        compacted = {}

        async def compact_mid_stream():
            await asyncio.sleep(duration_s / 3)
            t0 = loop.time()
            gen = await ctl.compact()
            compacted.update(gen=gen, wall_s=loop.time() - t0)

        async def traffic():
            i = 0
            pending = []

            def check(qi):
                def cb(fut):
                    msg = fut.result() if not fut.cancelled() else None
                    if msg is None or not msg.get("ok", False):
                        bad[0] += 1
                    elif msg["result"] != expected[qi]:
                        mismatches[0] += 1
                return cb

            while loop.time() < stop:
                qi = i % len(queries)
                fut = ws.submit(queries[qi], THETA)
                fut.add_done_callback(check(qi))
                pending.append(fut)
                sent[0] += 1
                i += 1
                if i % 64 == 0:
                    await asyncio.gather(*pending)
                    pending.clear()
            await asyncio.gather(*pending, return_exceptions=True)

        await asyncio.gather(traffic(), compact_mid_stream())
        answered = srv.metrics.snapshot()["counters"]["responses_total"]
        await ws.close()
        await ctl.close()
    return {"mode": "promotion_soak", "sent": sent[0],
            "answered": answered, "bad": bad[0],
            "mismatches": mismatches[0],
            "gen_before": gen_before, "gen_after": compacted.get("gen"),
            "compact_wall_s": compacted.get("wall_s")}


def run(quick: bool = True, smoke_seconds: float | None = None) -> dict:
    n_docs, doc_len = (400, 150) if quick else (3000, 300)
    n_q, q_len = (96, 80) if quick else (512, 120)
    closed_s = 1.2 if quick else 4.0
    open_s = 2.5 if quick else 8.0
    soak_s = smoke_seconds if smoke_seconds is not None else \
        (4.0 if quick else 15.0)

    docs = _corpus(n_docs, doc_len)
    queries = _queries(docs, n_q, q_len)
    soak_docs = _uniform_corpus(n_docs, doc_len, seed=3)
    delta_docs = _uniform_corpus(max(16, n_docs // 10), doc_len, seed=9)
    soak_queries = _queries(soak_docs, n_q, q_len, seed=4)

    with tempfile.TemporaryDirectory() as tmp:
        # latency study: tf-idf weighting (Zipf text needs IDF to keep the
        # probe selective); soak: multiset (corpus-free scheme, so the
        # from-scratch oracle over base+delta is scheme-identical)
        store = str(Path(tmp) / "idx")
        Aligner.build(docs, similarity="tfidf", seed=2, k=K,
                      pipeline="columnar", store=store)
        soak_store = str(Path(tmp) / "idx_soak")
        Aligner.build(soak_docs, similarity="multiset", seed=2, k=K,
                      pipeline="columnar", store=soak_store)

        # -- closed-loop + serial-capacity calibration ----------------------
        serial_rows, _ = asyncio.run(_bench_config(
            store, queries, max_batch=1, linger_us=0.0,
            closed_cs=(1, 16), closed_s=closed_s, open_rate=None,
            open_s=0.0))
        serial_capacity = max(r["qps"] for r in serial_rows)
        rate = 1.2 * serial_capacity

        batched_rows, batched_open = asyncio.run(_bench_config(
            store, queries, max_batch=32, linger_us=1000.0,
            closed_cs=(1, 16), closed_s=closed_s, open_rate=rate,
            open_s=open_s))
        _, serial_open = asyncio.run(_bench_config(
            store, queries, max_batch=1, linger_us=0.0,
            closed_cs=(), closed_s=0.0, open_rate=rate, open_s=open_s))

        # -- mid-traffic promotion ------------------------------------------
        soak = asyncio.run(_promotion_soak(soak_store, soak_docs,
                                           delta_docs, soak_queries,
                                           soak_s))

    rows = serial_rows + batched_rows + [serial_open, batched_open, soak]
    print_table("serve: closed-loop", serial_rows + batched_rows)
    print_table("serve: open-loop @ 1.2x serial capacity",
                [serial_open, batched_open])
    print_table("serve: promotion soak", [soak])

    claims = {
        # the tentpole claim: at >= 16 in flight under Zipf open-loop
        # overload, dynamic batching beats serial dispatch on p99
        "server_p99_batched_le_serial":
            batched_open["p99_ms"] <= serial_open["p99_ms"]
            and serial_open["peak_inflight"] >= 16
            and batched_open["peak_inflight"] >= 16,
        # a compaction mid-traffic loses nothing and corrupts nothing
        "no_dropped_requests_across_promotion":
            soak["sent"] == soak["answered"] and soak["bad"] == 0
            and soak["mismatches"] == 0
            and soak["gen_after"] == soak["gen_before"] + 1,
        # closed-loop single-client sanity: batching costs nothing when
        # there is nothing to coalesce (within 2.5x on p50)
        "server_batched_c1_overhead_bounded":
            batched_rows[0]["p50_ms"] <= 2.5 * serial_rows[0]["p50_ms"],
    }
    rec = {"suite": "serve", "quick": quick,
           "config": {"n_docs": n_docs, "doc_len": doc_len, "k": K,
                      "theta": THETA, "n_queries": n_q, "q_len": q_len,
                      "open_rate_qps": rate,
                      "serial_capacity_qps": serial_capacity},
           "rows": rows, "claims": claims,
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}
    save_result("serve", rec)
    for name, ok in claims.items():
        print(f"  [{'PASS' if ok else 'FAIL'}] {name}")
    return rec


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser("python -m benchmarks.bench_serve")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI soak: quick sizes with a ~30 s total budget")
    args = ap.parse_args(argv)
    rec = run(quick=not args.full,
              smoke_seconds=10.0 if args.smoke else None)
    return 0 if all(rec["claims"].values()) else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
