"""§Roofline reporter: read results/dryrun/*.json, print/emit the full
(arch x shape x mesh) table with the three roofline terms, bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio, bytes-per-device, and what-to-move-next notes.

Also measures the serving-side transfer roofline (PR 10): a multi-batch
soak of the fused device query pipeline, accounting the logical bytes that
cross the host<->device bus per batch.  With the ProbeArena resident, the
steady state should move only the probe inputs up and the compressed
result grids/extents down — never the arena or the window rows.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .common import print_table, save_result

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"

_NOTE = {
    "compute": "compute-bound: raise MXU utilization (larger microbatch, "
               "fewer remat recomputes)",
    "memory": "HBM-bound: fuse/reuse (bigger scan chunks, fewer f32 "
              "round-trips, flash-style attention)",
    "collective": "ICI-bound: cut gathers (fewer microbatch re-gathers, "
                  "reduce-scatter grads, bf16 collectives)",
}


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(mesh: str = "single") -> list[dict]:
    rows = []
    for r in load_records(mesh):
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "Tc_s": "-", "Tm_s": "-", "Tn_s": "-",
                         "bound": "skip", "MF/HF": "-", "MFU*": "-",
                         "GB/dev": "-"})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "Tc_s": "ERR", "Tm_s": "-", "Tn_s": "-",
                         "bound": "error", "MF/HF": "-", "MFU*": "-",
                         "GB/dev": "-"})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "Tc_s": round(rf["compute_s"], 4),
            "Tm_s": round(rf["memory_s"], 4),
            "Tn_s": round(rf["collective_s"], 4),
            "bound": rf["bottleneck"],
            "MF/HF": round(r["model_vs_hlo_flops"], 3),
            "MFU*": round(r.get("model_flops_util", 0.0), 3),
            "GB/dev": round(r.get("live_bytes_per_device", 0) / 1e9, 2),
        })
    return rows


def fused_pipeline_row(quick: bool = True) -> tuple[list[dict], dict]:
    """Soak the fused device query pipeline and account per-batch bus
    traffic.  Returns (table rows, claims)."""
    from repro.core import IndexBuilder, QueryOptions, make_scheme, \
        batch_query
    from repro.core.device_plan import reset_transfer_stats, transfer_stats

    rng = np.random.default_rng(17)
    n_docs, doc_len = (64, 200) if quick else (160, 320)
    pass_len, n_pass = 110, 12
    passages = [rng.integers(0, 1 << 20, size=pass_len).astype(np.int64)
                for _ in range(n_pass)]
    docs = []
    for i in range(n_docs):
        d = rng.integers(0, 1 << 20, size=doc_len).astype(np.int64)
        o = int(rng.integers(0, doc_len - pass_len))
        d[o:o + pass_len] = passages[i % n_pass]
        docs.append(d)
    scheme = make_scheme("multiset", seed=23, k=16)
    idx = IndexBuilder(scheme=scheme).build(docs).freeze()

    B, n_batches = (32, 4) if quick else (128, 8)
    opts = QueryOptions(plan="device")
    reset_transfer_stats()
    n_results = 0
    for _ in range(n_batches):
        qs = []
        for _q in range(B):
            p = passages[int(rng.integers(0, n_pass))]
            o = int(rng.integers(0, pass_len - 90))
            qs.append(p[o:o + 90].copy())
        res = batch_query(idx, qs, 0.5, options=opts)
        n_results += sum(len(r) for r in res)
    st = transfer_stats()
    per_up = st["h2d_bytes"] / st["batches"]
    per_down = st["d2h_bytes"] / st["batches"]
    rows = [{"stage": "arena residency (once)", "batches": st["batches"],
             "up_KB": round(st["arena_bytes"] / 1e3, 1), "down_KB": 0.0,
             "uploads": st["arena_uploads"]},
            {"stage": "fused pipeline (per batch)", "batches": st["batches"],
             "up_KB": round(per_up / 1e3, 1),
             "down_KB": round(per_down / 1e3, 1),
             "uploads": 0}]
    claims = {
        # steady state ships probe inputs up and result grids/extents down;
        # the arena (and the window rows it indexes) crossed the bus once,
        # so per-batch traffic stays well under one arena re-upload
        "device_pipeline_transfers_le_results_only":
            st["arena_uploads"] == 1 and st["batches"] == n_batches
            and per_up + per_down < st["arena_bytes"],
    }
    return rows, claims


def run(quick: bool = True) -> dict:
    rows = table("single")
    print_table("Roofline terms per (arch x shape), single pod 16x16 "
                "(Tc/Tm/Tn seconds per step; MFU* = model-useful FLOPs over "
                "peak x bottleneck-time)", rows)
    multi = table("multi")
    ok_multi = sum(1 for r in multi if r["bound"] not in ("error",))
    print(f"\nmulti-pod (2x16x16): {ok_multi}/{len(multi)} cells lower+"
          f"compile cleanly (full table in EXPERIMENTS.md)")
    bounds = {}
    for r in rows:
        bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
    fused_rows, claims = fused_pipeline_row(quick)
    print_table("fused device query pipeline: host<->device bytes "
                "(arena resident across the soak)", fused_rows)
    rec = {"single": rows, "multi": multi, "bound_histogram": bounds,
           "fused_pipeline": fused_rows, "claims": claims}
    save_result("roofline", rec)
    return rec


def markdown(mesh: str = "single") -> str:
    rows = table(mesh)
    if not rows:
        return "(no dry-run records)"
    hdr = "| arch | shape | Tc (s) | Tm (s) | Tn (s) | bound | MODEL/HLO | MFU* | GB/dev |"
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(f"| {r['arch']} | {r['shape']} | {r['Tc_s']} | "
                     f"{r['Tm_s']} | {r['Tn_s']} | {r['bound']} | "
                     f"{r['MF/HF']} | {r['MFU*']} | {r['GB/dev']} |")
    return "\n".join(lines)
