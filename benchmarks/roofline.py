"""§Roofline reporter: read results/dryrun/*.json, print/emit the full
(arch x shape x mesh) table with the three roofline terms, bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio, bytes-per-device, and what-to-move-next notes.
"""

from __future__ import annotations

import json
from pathlib import Path

from .common import print_table, save_result

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"

_NOTE = {
    "compute": "compute-bound: raise MXU utilization (larger microbatch, "
               "fewer remat recomputes)",
    "memory": "HBM-bound: fuse/reuse (bigger scan chunks, fewer f32 "
              "round-trips, flash-style attention)",
    "collective": "ICI-bound: cut gathers (fewer microbatch re-gathers, "
                  "reduce-scatter grads, bf16 collectives)",
}


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def table(mesh: str = "single") -> list[dict]:
    rows = []
    for r in load_records(mesh):
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "Tc_s": "-", "Tm_s": "-", "Tn_s": "-",
                         "bound": "skip", "MF/HF": "-", "MFU*": "-",
                         "GB/dev": "-"})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "Tc_s": "ERR", "Tm_s": "-", "Tn_s": "-",
                         "bound": "error", "MF/HF": "-", "MFU*": "-",
                         "GB/dev": "-"})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "Tc_s": round(rf["compute_s"], 4),
            "Tm_s": round(rf["memory_s"], 4),
            "Tn_s": round(rf["collective_s"], 4),
            "bound": rf["bottleneck"],
            "MF/HF": round(r["model_vs_hlo_flops"], 3),
            "MFU*": round(r.get("model_flops_util", 0.0), 3),
            "GB/dev": round(r.get("live_bytes_per_device", 0) / 1e9, 2),
        })
    return rows


def run(quick: bool = True) -> dict:
    rows = table("single")
    print_table("Roofline terms per (arch x shape), single pod 16x16 "
                "(Tc/Tm/Tn seconds per step; MFU* = model-useful FLOPs over "
                "peak x bottleneck-time)", rows)
    multi = table("multi")
    ok_multi = sum(1 for r in multi if r["bound"] not in ("error",))
    print(f"\nmulti-pod (2x16x16): {ok_multi}/{len(multi)} cells lower+"
          f"compile cleanly (full table in EXPERIMENTS.md)")
    bounds = {}
    for r in rows:
        bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
    rec = {"single": rows, "multi": multi, "bound_histogram": bounds}
    save_result("roofline", rec)
    return rec


def markdown(mesh: str = "single") -> str:
    rows = table(mesh)
    if not rows:
        return "(no dry-run records)"
    hdr = "| arch | shape | Tc (s) | Tm (s) | Tn (s) | bound | MODEL/HLO | MFU* | GB/dev |"
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(f"| {r['arch']} | {r['shape']} | {r['Tc_s']} | "
                     f"{r['Tm_s']} | {r['Tn_s']} | {r['bound']} | "
                     f"{r['MF/HF']} | {r['MFU*']} | {r['GB/dev']} |")
    return "\n".join(lines)
