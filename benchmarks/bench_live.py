"""Live-serving study — the incremental-serve subsystem's two claims:

* ``live_query_overhead_le_1_2x`` — serving a corpus as (frozen mmap
  store + small mutable delta) must cost <= 1.2x the batched query
  latency of serving the SAME corpus fully frozen, with the delta held at
  <= 5% of the corpus (the steady state between compactions: the arena
  probe covers the frozen bulk, the delta adds one dict probe).
* ``compacted_equals_scratch_build`` — merge-compaction (frozen tables +
  delta streamed through the columnar pipeline into a new store
  generation) must produce CSR arrays bit-identical to a from-scratch
  build of the union corpus, and serve block-identical results.

An add-throughput row documents the write path (delta ingest is the dict
builder, unchanged); a post-compaction timing row shows the live index
returning to frozen-only speed once the delta is folded in.

A durability table compares acked-adds/sec across WAL fsync policies
(no WAL / fsync-per-record / group commit / async) over the same ingest
stream, backing a third claim:

* ``wal_group_commit_amortizes_fsync`` — group commit must issue at
  most 1/4 the fsyncs of the per-record policy for the same
  fully-acknowledged ingest (a deterministic counter comparison, not a
  timing gate — wall-clock fsync cost varies wildly across storage).
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from repro.core import IndexBuilder, batch_query, make_scheme, save_index
from repro.core.live import LiveIndex
from repro.wal import WalConfig

from .common import print_table, save_result, timed, zipf_text

THETA = 0.5


def _blocks(res):
    return [[(a.text_id, a.blocks) for a in r] for r in res]


def _tables_identical(a, b) -> bool:
    if len(a.tables) != len(b.tables):
        return False
    for ta, tb in zip(a.tables, b.tables):
        if ta.kind != tb.kind or ta.kint_min != tb.kint_min:
            return False
        if not (np.array_equal(ta.keys, tb.keys)
                and np.array_equal(ta.offsets, tb.offsets)
                and np.array_equal(ta.windows, tb.windows)):
            return False
    return True


# write-path durability policies: None = WAL off, otherwise the
# fsync_every_n knob (1 = per-record, 8 = group commit, 0 = async —
# records reach the OS but the ack barrier is the explicit commit)
_POLICIES = [
    ("no-wal", None),
    ("wal-per-record", 1),
    ("wal-group-8", 8),
    ("wal-async", 0),
]


def _durability_rows(scheme, base, delta):
    """Acked-adds/sec per fsync policy over the same ingest stream.

    "Acked" means what the serve path means by it: for the per-record
    and group policies every record is durable when the timer stops
    (add_text fsyncs inline), for async we stop the clock after the
    explicit ``wal_commit`` barrier, and with no WAL an add is "acked"
    the moment it is indexed (crash loses it — that is the baseline the
    table prices).
    """
    rows = []
    fsyncs = {}
    for name, every_n in _POLICIES:
        with tempfile.TemporaryDirectory() as d:
            root = Path(d) / "idx"
            save_index(IndexBuilder(scheme=scheme).build(base).freeze(),
                       root)
            wal = (WalConfig(fsync_every_n=every_n)
                   if every_n is not None else False)
            live = LiveIndex.open(root, mmap=True, wal=wal)

            def ingest():
                for i, t in enumerate(delta):
                    live.add_text(t, request_id=f"bench-{i}")
                if live.wal is not None:
                    live.wal_commit()

            _, t = timed(ingest)
            n_fsync = (live.wal.counters["fsyncs"]
                       if live.wal is not None else 0)
            fsyncs[name] = n_fsync
            rows.append({"policy": name, "docs": len(delta),
                         "acked_docs_per_s": len(delta) / t,
                         "seconds": t, "fsyncs": n_fsync})
    return rows, fsyncs


def run(quick: bool = True) -> dict:
    k = 16
    n_docs, doc_len = (40, 600) if quick else (160, 1200)
    n_delta = max(1, n_docs // 20)                    # the <= 5% steady state
    scheme = make_scheme("multiset", seed=44, k=k)
    base = [zipf_text(doc_len, seed=900 + i) for i in range(n_docs)]
    delta = [zipf_text(doc_len, seed=2900 + i) for i in range(n_delta)]
    union = base + delta

    B = 32
    rng = np.random.default_rng(77)
    qs = [union[int(rng.integers(len(union)))][:doc_len // 3]
          for _ in range(B - 8)]
    qs += [zipf_text(doc_len // 3, seed=5000 + i) for i in range(8)]

    # the frozen-only baseline serves the SAME union corpus from CSR arrays
    frozen_union = IndexBuilder(scheme=scheme).build(union).freeze()
    frozen_union.arena()                              # warm the fused arena

    with tempfile.TemporaryDirectory() as d:
        root = Path(d) / "idx"
        save_index(IndexBuilder(scheme=scheme).build(base).freeze(), root)
        live = LiveIndex.open(root, mmap=True)
        _, t_ingest = timed(lambda: [live.add_text(t) for t in delta])
        # warm BOTH paths with the full batch: the live side serves from
        # mmap'd arrays, and an unwarmed first round would time page-ins
        # instead of the merge (a systematic, load-correlated bias)
        live_res = live.batch_query(qs, THETA)
        exp = _blocks(batch_query(frozen_union, qs, THETA))

        # pair the two measurements back-to-back inside each round and
        # gate on the MEDIAN of the per-round ratios: pairing cancels
        # load drift that spans a round, the median tolerates a noisy
        # round hitting either leg, and (unlike a min) a real merge-path
        # regression cannot hide behind one deflated denominator
        ratios = []
        t_frozen = t_live = float("inf")
        frozen_res = None
        for _ in range(5):
            frozen_res, tf = timed(
                lambda: batch_query(frozen_union, qs, THETA))
            live_res, tl = timed(lambda: live.batch_query(qs, THETA))
            ratios.append(tl / tf)
            t_frozen, t_live = min(t_frozen, tf), min(t_live, tl)
        overhead = float(np.median(ratios))
        overhead_min = float(np.min(ratios))
        live_equal = _blocks(live_res) == exp and _blocks(frozen_res) == exp

        _, t_compact = timed(live.compact)
        compacted_identical = _tables_identical(live.frozen, frozen_union)
        (post_res), t_post = timed(
            lambda: live.batch_query(qs, THETA), repeat=3)
        post_equal = _blocks(post_res) == exp

    rows = [
        {"path": "frozen-only", "docs": len(union), "delta": 0,
         "batch_s": t_frozen, "vs_frozen": 1.0, "equal": True},
        {"path": "live (frozen+delta)", "docs": len(union), "delta": n_delta,
         "batch_s": t_live, "vs_frozen": overhead,
         "vs_frozen_min": overhead_min, "equal": live_equal},
        {"path": "live (post-compact)", "docs": len(union), "delta": 0,
         "batch_s": t_post, "vs_frozen": t_post / t_frozen,
         "equal": post_equal},
    ]
    write_rows = [
        {"op": "delta ingest", "docs": n_delta,
         "docs_per_s": n_delta / t_ingest, "seconds": t_ingest},
        {"op": "compact (merge+promote)", "docs": len(union),
         "docs_per_s": len(union) / t_compact, "seconds": t_compact},
    ]
    # durability study: same ingest stream under each WAL fsync policy;
    # 32 docs give group-8 four full commit groups, so the counter
    # comparison below is exact and load-independent
    dur_docs = [zipf_text(doc_len // 2, seed=7000 + i) for i in range(32)]
    durability_rows, fsyncs = _durability_rows(scheme, base, dur_docs)

    print_table(f"live serving: batched query (B={B}, k={k}, "
                f"delta={n_delta}/{len(union)} docs)", rows)
    print_table("live serving: write path", write_rows)
    print_table("live serving: write-path durability "
                f"({len(dur_docs)} acked adds per policy)", durability_rows)

    claims = {
        # the delta is <= 5% of the corpus; merging its dict probe into
        # the arena-probed sweep must stay within 1.2x of frozen-only
        "live_query_overhead_le_1_2x": bool(overhead <= 1.2 and live_equal),
        # compaction = from-scratch build, bit-for-bit AND result-for-result
        "compacted_equals_scratch_build": bool(compacted_identical
                                               and post_equal),
        # group commit must amortize the durability barrier: <= 1/4 the
        # fsyncs of per-record for the same fully-acked ingest
        "wal_group_commit_amortizes_fsync": bool(
            fsyncs["wal-per-record"] >= len(dur_docs)
            and fsyncs["wal-group-8"] * 4 <= fsyncs["wal-per-record"]),
    }
    rec = {"query_rows": rows, "write_rows": write_rows,
           "durability_rows": durability_rows,
           "overhead": overhead, "overhead_min": overhead_min,
           "overhead_rounds": ratios, "claims": claims}
    save_result("live", rec)
    return rec
