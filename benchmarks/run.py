"""Benchmark harness entry point: one module per paper table/figure plus
the roofline reporter.  ``python -m benchmarks.run [--full] [--only NAME]``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from . import (bench_active_opt, bench_build, bench_live, bench_query,
               bench_serve, bench_sketch_kernels, bench_vs_allalign,
               bench_weights, roofline)

SUITES = {
    "active_opt": bench_active_opt.run,      # paper Fig. 5
    "weights": bench_weights.run,            # paper Fig. 6
    "vs_allalign": bench_vs_allalign.run,    # paper Fig. 7
    "query": bench_query.run,                # paper §6 query study
    "build": bench_build.run,                # §6 construction study
    "live": bench_live.run,                  # incremental-serve study
    "serve": bench_serve.run,                # serving front-end study
    "sketch_kernels": bench_sketch_kernels.run,
    "roofline": roofline.run,                # EXPERIMENTS.md §Roofline
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow); default is scaled-down")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args(argv)

    failures = []
    all_claims = {}
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        try:
            rec = fn(quick=not args.full)
            claims = rec.get("claims", {})
            all_claims[name] = claims
            for cname, ok in claims.items():
                mark = "PASS" if ok else "FAIL"
                print(f"  [{mark}] {cname}")
                if not ok:
                    failures.append(f"{name}:{cname}")
        except Exception as e:  # pragma: no cover
            failures.append(f"{name}:exception:{e}")
            import traceback
            traceback.print_exc()
        print(f"  ({time.time() - t0:.1f}s)")

    print("\n==== paper-claim summary ====")
    print(json.dumps(all_claims, indent=1))
    if failures:
        print("FAILURES:", failures)
        return 1
    print("all benchmark claims hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
