"""Figure 6 reproduction: weighted Jaccard under binary / logarithmic /
raw-count / squared TF weights -- partition size, partition time, query
latency vs n and vs f (MonoActive; k scaled down for CPU).

Paper claims: size(binary) < size(log) < size(raw) < size(squared);
binary ~O(n), log ~O(n log log f), raw/squared ~O(n log f) (Lemma 13).
"""

from __future__ import annotations

import numpy as np

from repro.core import ICWS, IndexBuilder, mono_active_icws
from repro.core.index import WeightedScheme
from repro.core.query import query
from repro.core.weights import WeightFn

from .common import controlled_f_text, print_table, save_result, timed, \
    zipf_text

TFS = ("binary", "log", "raw", "squared")


def run(quick: bool = True) -> dict:
    icws = ICWS.from_seed(11, 2)
    rows_n, rows_f, rows_q = [], [], []

    ns = [1000, 3000, 10000] if quick else [1000, 3000, 10000, 30000]
    for n in ns:
        text = zipf_text(n, seed=4)
        row = {"n": n}
        for tf in TFS:
            w = WeightFn(tf=tf, idf="unary")
            parts, t = timed(lambda: [mono_active_icws(text, h, w)
                                      for h in icws])
            row[f"{tf}_windows"] = sum(len(p) for p in parts)
            row[f"{tf}_s"] = t
        rows_n.append(row)

    n = 5000
    fs = [10, 100, 500] if quick else [10, 100, 500, 1500]
    for f in fs:
        text = controlled_f_text(n, f, seed=5)
        row = {"f": f}
        for tf in TFS:
            w = WeightFn(tf=tf, idf="unary")
            parts, t = timed(lambda: [mono_active_icws(text, h, w)
                                      for h in icws])
            row[f"{tf}_windows"] = sum(len(p) for p in parts)
            row[f"{tf}_s"] = t
        rows_f.append(row)

    # query latency per weight function (small corpus)
    k = 8
    rng = np.random.default_rng(6)
    docs = [zipf_text(1500, seed=100 + i) for i in range(6)]
    qtext = docs[2][200:300].copy()
    for tf in TFS:
        scheme = WeightedScheme(weight=WeightFn(tf=tf, idf="unary"),
                                seed=3, k=k)
        idx = IndexBuilder(scheme=scheme).build(docs)
        res, t = timed(lambda: query(idx, qtext, 0.6), repeat=3)
        rows_q.append({"tf": tf, "windows": idx.num_windows,
                       "query_s": t, "hits": len(res)})

    print_table("Fig6(a-d): partition size/time vs n (k=2)", rows_n)
    print_table("Fig6(g-j): partition size/time vs f (n=5000)", rows_f)
    print_table("Fig6(e,f,k,l): query latency by weight fn (k=8)", rows_q)

    last = rows_f[-1]
    claims = {
        "size_order_binary<log<raw<squared": bool(
            last["binary_windows"] <= last["log_windows"]
            <= last["raw_windows"] <= last["squared_windows"]),
        "binary_flat_in_f": bool(
            rows_f[-1]["binary_windows"] < 1.15 * rows_f[0]["binary_windows"]),
        "every_query_finds_planted_hit": all(r["hits"] >= 1 for r in rows_q),
    }
    rec = {"vs_n": rows_n, "vs_f": rows_f, "query": rows_q, "claims": claims}
    save_result("weights", rec)
    return rec
