"""Figure 5 reproduction: MonoAll vs MonoActive partition time vs n, f, k.

Paper claims reproduced (scaled to this container):
  (a,b) both grow quasi-linearly with n; MonoActive consistently faster;
  (c,d) MonoActive ~flat in f, MonoAll ~linear in f;
  (e,f) both linear in sketch size k.
"""

from __future__ import annotations

from repro.core import UniversalHash, mono_active_multiset, mono_all_multiset

from .common import controlled_f_text, print_table, save_result, timed, \
    zipf_text


def run(quick: bool = True) -> dict:
    hashers = UniversalHash.from_seed(42, 4)
    rows_n, rows_f, rows_k = [], [], []

    ns = [1000, 3000, 10000] if quick else [1000, 3000, 10000, 30000, 100000]
    for n in ns:
        text = zipf_text(n, seed=1)
        _, t_all = timed(lambda: [mono_all_multiset(text, h)
                                  for h in hashers[:2]])
        p, t_act = timed(lambda: [mono_active_multiset(text, h)
                                  for h in hashers[:2]])
        rows_n.append({"n": n, "mono_all_s": t_all, "mono_active_s": t_act,
                       "speedup": t_all / t_act,
                       "windows": sum(len(x) for x in p)})

    n = 5000
    fs = [10, 100, 500] if quick else [10, 100, 500, 1000, 2500]
    for f in fs:
        text = controlled_f_text(n, f, seed=2)
        _, t_all = timed(lambda: [mono_all_multiset(text, h)
                                  for h in hashers[:2]])
        p, t_act = timed(lambda: [mono_active_multiset(text, h)
                                  for h in hashers[:2]])
        rows_f.append({"f": f, "mono_all_s": t_all, "mono_active_s": t_act,
                       "speedup": t_all / t_act,
                       "windows": sum(len(x) for x in p)})

    text = zipf_text(3000, seed=3)
    for k in ([2, 8] if quick else [2, 8, 32, 64]):
        hk = UniversalHash.from_seed(7, k)
        _, t_act = timed(lambda: [mono_active_multiset(text, h) for h in hk])
        rows_k.append({"k": k, "mono_active_s": t_act,
                       "per_hash_s": t_act / k})

    print_table("Fig5(a,b): partition time vs n (k=2)", rows_n)
    print_table("Fig5(c,d): partition time vs max frequency f (n=5000)",
                rows_f)
    print_table("Fig5(e,f): partition time vs sketch size k (n=3000)", rows_k)

    # paper-claim checks
    claims = {
        "active_faster_everywhere": all(r["speedup"] > 1.0 for r in rows_f),
        "active_speedup_grows_with_f":
            rows_f[-1]["speedup"] > rows_f[0]["speedup"],
        "k_scaling_linear":
            abs(rows_k[-1]["per_hash_s"] / rows_k[0]["per_hash_s"] - 1) < 0.8,
    }
    rec = {"vs_n": rows_n, "vs_f": rows_f, "vs_k": rows_k, "claims": claims}
    save_result("active_opt", rec)
    return rec
